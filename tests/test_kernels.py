"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in repro.kernels.ref."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.serving.paged_cache import KVPageSpec

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,sq,skv,d", [
    (1, 4, 4, 16, 16, 32),       # MHA, square
    (2, 8, 2, 24, 48, 64),       # GQA, rectangular, non-multiple of block
    (1, 4, 1, 7, 133, 32),       # MQA, ragged
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 9), (False, 0)])
def test_flash_attention_sweep(b, h, kv, sq, skv, d, dtype, causal, window):
    if not causal and sq != skv:
        pytest.skip("non-causal used for encoder (square) only")
    ks = jax.random.split(jax.random.key(hash((b, h, sq)) % 2**31), 3)
    q = _rand(ks[0], (b, h, sq, d), dtype)
    k = _rand(ks[1], (b, kv, skv, d), dtype)
    v = _rand(ks[2], (b, kv, skv, d), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=16, block_k=16, force_interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,d,bs,pages", [
    (2, 4, 4, 32, 8, 4),
    (3, 8, 2, 64, 16, 3),
    (1, 4, 1, 32, 4, 7),
])
@pytest.mark.parametrize("window", [0, 11])
def test_paged_attention_sweep(b, h, kv, d, bs, pages, dtype, window):
    n_blocks = b * pages + 1
    ks = jax.random.split(jax.random.key(hash((b, h, d)) % 2**31), 4)
    q = _rand(ks[0], (b, h, d), dtype)
    k_pool = _rand(ks[1], (n_blocks, bs, kv, d), dtype)
    v_pool = _rand(ks[2], (n_blocks, bs, kv, d), dtype)
    rng = np.random.default_rng(0)
    table = rng.permutation(n_blocks - 1)[:b * pages].reshape(b, pages) + 1
    table = jnp.asarray(table, jnp.int32)
    seq_lens = jnp.asarray(rng.integers(1, bs * pages + 1, b), jnp.int32)
    got = ops.paged_attention(q, k_pool, v_pool, table, seq_lens,
                              window=window, force_interpret=True)
    want = ref.paged_attention_ref(q, k_pool, v_pool, table, seq_lens,
                                   window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype])


@pytest.mark.parametrize("layout", ["nbhd", "nhbd", "nhdb"])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_gather_scatter_pages_sweep(layout, dtype):
    spec = KVPageSpec(block_size=8, layout=layout, dtype=dtype, kv_heads=4,
                      head_dim=16)
    pool = jax.random.normal(jax.random.key(0),
                             spec.pool_shape(10)).astype(spec.jdtype)
    ids = jnp.asarray([3, 1, 7], jnp.int32)
    got = ops.gather_pages(spec, pool, ids, force_interpret=True)
    want = ref.gather_pages_ref(spec, pool, ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    canon = jax.random.normal(jax.random.key(1),
                              (3, 8, 4, 16)).astype(spec.jdtype)
    got_p = ops.scatter_pages(spec, pool, ids, canon, force_interpret=True)
    want_p = ref.scatter_pages_ref(spec, pool, ids, canon)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))


@pytest.mark.parametrize("src_layout,dst_layout,src_bs,dst_bs,src_dt,dst_dt", [
    ("nbhd", "nhbd", 8, 4, "float32", "float32"),
    ("nhdb", "nbhd", 4, 16, "float32", "bfloat16"),
    ("nhbd", "nhdb", 16, 8, "bfloat16", "float32"),
])
def test_repack_vendor_alignment_sweep(src_layout, dst_layout, src_bs,
                                       dst_bs, src_dt, dst_dt):
    """The paper's Fig. 3 path: P layout/blocksize/dtype → D's, exactly."""
    kv, hd, seq = 2, 16, 27
    src = KVPageSpec(src_bs, src_layout, src_dt, kv, hd)
    dst = KVPageSpec(dst_bs, dst_layout, dst_dt, kv, hd)
    nb_s = src.blocks_for(seq)
    nb_d = dst.blocks_for(seq)
    src_pool = jax.random.normal(jax.random.key(0),
                                 src.pool_shape(nb_s + 2)).astype(src.jdtype)
    dst_pool = jnp.zeros(dst.pool_shape(nb_d + 2), dst.jdtype)
    src_blocks = jnp.arange(1, nb_s + 1, dtype=jnp.int32)
    dst_blocks = jnp.arange(1, nb_d + 1, dtype=jnp.int32)
    got = ops.repack(src, dst, src_pool, src_blocks, dst_pool, dst_blocks,
                     seq, force_interpret=True)
    want = ref.repack_ref(src, dst, src_pool, src_blocks, dst_pool,
                          dst_blocks, seq)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # token stream identical through the round trip
    src_canon = ref.gather_pages_ref(src, src_pool, src_blocks,
                                     out_dtype=dst.jdtype)
    dst_canon = ref.gather_pages_ref(dst, got, dst_blocks)
    a = src_canon.reshape(-1, kv, hd)[:seq]
    b = dst_canon.reshape(-1, kv, hd)[:seq]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
