"""Property tests (hypothesis) for the heterogeneous compatible module —
the paper's core contribution: layout round-trips, TP merge/split identity,
precision wire bounds."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

import jax
import jax.numpy as jnp

from repro.core.compat import parallel_align, precision
from repro.core.compat.precision import WireFormat
from repro.serving import paged_cache as PC


# --------------------------------------------------------------------------- #
# Layout (VRAM management alignment)
# --------------------------------------------------------------------------- #
@given(layout=st.sampled_from(PC.LAYOUTS), nb=st.integers(1, 4),
       bs=st.sampled_from([4, 8, 16]), kv=st.sampled_from([1, 2, 4]),
       hd=st.sampled_from([8, 16]))
def test_layout_roundtrip_identity(layout, nb, bs, kv, hd):
    spec = PC.KVPageSpec(bs, layout, "float32", kv, hd)
    canon = np.random.default_rng(0).normal(
        size=(nb, bs, kv, hd)).astype(np.float32)
    pages = PC.pages_from_canonical(spec, jnp.asarray(canon))
    back = PC.pages_to_canonical(spec, pages)
    np.testing.assert_array_equal(np.asarray(back), canon)


@given(src_bs=st.sampled_from([4, 8, 16]), dst_bs=st.sampled_from([4, 8, 16]),
       src_layout=st.sampled_from(PC.LAYOUTS),
       dst_layout=st.sampled_from(PC.LAYOUTS),
       seq=st.integers(1, 40))
def test_flatten_to_1d_transfer_preserves_tokens(src_bs, dst_bs, src_layout,
                                                 dst_layout, seq):
    """The paper's general method: 1-D wire stream is layout-invariant."""
    kv, hd = 2, 8
    src = PC.KVPageSpec(src_bs, src_layout, "float32", kv, hd)
    dst = PC.KVPageSpec(dst_bs, dst_layout, "float32", kv, hd)
    kvd = np.random.default_rng(1).normal(size=(seq, kv, hd)).astype(np.float32)
    sp = PC.init_pool(src, src.blocks_for(seq))
    sp = PC.scatter_sequence(src, sp, jnp.arange(src.blocks_for(seq)),
                             jnp.asarray(kvd))
    wire = PC.gather_sequence(src, sp, jnp.arange(src.blocks_for(seq)), seq)
    dp = PC.init_pool(dst, dst.blocks_for(seq))
    dp = PC.scatter_sequence(dst, dp, jnp.arange(dst.blocks_for(seq)), wire)
    got = PC.gather_sequence(dst, dp, jnp.arange(dst.blocks_for(seq)), seq)
    np.testing.assert_array_equal(np.asarray(got), kvd)


# --------------------------------------------------------------------------- #
# Parallel-strategy alignment (Fig. 4)
# --------------------------------------------------------------------------- #
@given(kv_heads=st.sampled_from([4, 8, 16]),
       tp_p=st.sampled_from([1, 2, 4, 8]), tp_d=st.sampled_from([1, 2, 4, 8]))
def test_tp_realign_merge_split_identity(kv_heads, tp_p, tp_d):
    if kv_heads % tp_p or kv_heads % tp_d:
        return
    s, hd = 6, 4
    full = np.random.default_rng(2).normal(
        size=(s, kv_heads, hd)).astype(np.float32)
    shards_p = [jnp.asarray(full[:, i * (kv_heads // tp_p):
                                 (i + 1) * (kv_heads // tp_p)])
                for i in range(tp_p)]
    shards_d = parallel_align.realign_shards(shards_p, tp_d)
    assert len(shards_d) == tp_d
    rebuilt = np.concatenate([np.asarray(x) for x in shards_d], axis=1)
    np.testing.assert_array_equal(rebuilt, full)
    # round-trip back to tp_p
    back = parallel_align.realign_shards(shards_d, tp_p)
    for a, b in zip(back, shards_p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(kv_heads=st.sampled_from([4, 8, 16]),
       tp_p=st.sampled_from([1, 2, 4]), tp_d=st.sampled_from([1, 2, 4]))
def test_transfer_pairs_cover_all_heads(kv_heads, tp_p, tp_d):
    edges = parallel_align.transfer_pairs(kv_heads, tp_p, tp_d)
    assert sum(h for _, _, h in edges) == kv_heads
    per_d = {}
    for p, d, h in edges:
        per_d[d] = per_d.get(d, 0) + h
    assert all(v == kv_heads // tp_d for v in per_d.values())


# --------------------------------------------------------------------------- #
# Precision alignment
# --------------------------------------------------------------------------- #
@given(dtype=st.sampled_from(["float32", "bfloat16", "float16"]))
def test_raw_wire_roundtrip(dtype):
    x = jnp.asarray(np.random.default_rng(3).normal(size=(10, 2, 8)),
                    jnp.dtype(dtype))
    wire = WireFormat("raw", dtype)
    pl, sc = precision.encode_wire(x, wire)
    back = precision.decode_wire(pl, sc, wire, jnp.dtype(dtype))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@given(scale=st.floats(0.01, 100.0))
def test_int8_wire_error_bound(scale):
    x = jnp.asarray(np.random.default_rng(4).normal(size=(32, 2, 16)),
                    jnp.float32) * scale
    wire = WireFormat("int8")
    pl, sc = precision.encode_wire(x, wire)
    assert pl.dtype == jnp.int8
    back = precision.decode_wire(pl, sc, wire, jnp.float32)
    err = np.max(np.abs(np.asarray(back) - np.asarray(x)))
    bound = np.max(np.abs(np.asarray(x)), axis=-1) / 127.0 * 0.5001 + 1e-6
    assert err <= bound.max() * 1.01 + 1e-6


def test_wire_bytes_accounting():
    assert precision.wire_bytes((4, 2, 8), WireFormat("raw", "bfloat16")) \
        == 4 * 2 * 8 * 2
    assert precision.wire_bytes((4, 2, 8), WireFormat("int8")) \
        == int(4 * 2 * 8 * (1 + 4 / 64))
