"""Chunked streamed P→D handoff (paper §III-B overlap).

Three layers of guarantees:

  1. *wire*: streaming a prefill package chunk-by-chunk (including chunk
     boundaries that straddle D-vendor block boundaries → read-modify-write
     re-paging) lands **bit-identical** D pools vs the monolithic wire, for
     raw/bf16/int8 formats.
  2. *compute*: incremental chunked prefill is token-exact vs monolithic
     prefill through the full serving stack.
  3. *scheduling*: with streaming enabled, a long prefill no longer blocks
     the tick — decode tokens are emitted while it is in flight.
"""
import numpy as np
import pytest

import jax

from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.kv_transfer import TransferEngine
from repro.models import model as M
from repro.serving.engine import Engine, VendorProfile
from repro.serving.request import Request, State
from repro.serving.scheduler import GlobalScheduler
from tests.conftest import TINY_FAMILIES

WIRES = [WireFormat("raw", "float32"), WireFormat("raw", "bfloat16"),
         WireFormat("int8")]


def _req(cfg, plen, rid="r0", max_new=6, seed=3):
    rng = np.random.default_rng(seed)
    r = Request(req_id=rid,
                prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                max_new_tokens=max_new)
    if cfg.is_enc_dec:
        r.frames = rng.normal(size=(10, cfg.d_model)).astype(np.float32)
    if cfg.frontend.kind == "vision":
        r.patches = rng.normal(size=(cfg.frontend.num_patches,
                                     cfg.d_model)).astype(np.float32)
    return r


def _pair(cfg, params, vd, mem_len=0):
    vp = VendorProfile("B", block_size=8, layout="nhbd",
                       kv_dtype="float32", tp=2)
    p = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
               max_seq_len=64, mem_len=mem_len, role="prefill")
    d = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
               max_seq_len=64, mem_len=mem_len, role="decode")
    return p, d


# --------------------------------------------------------------------- #
# 1. wire: bit-for-bit streamed == monolithic
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("family", ["dense", "mla"])
@pytest.mark.parametrize("wire", WIRES, ids=lambda w: f"{w.kind}-{w.dtype}")
def test_streamed_handoff_bitwise_equals_monolithic(family, wire):
    """Same prefill package, shipped monolithically vs streamed in chunks
    whose boundaries straddle the D vendor's 4-token blocks: every D-side
    pool array must match bit for bit, as must the first token and the
    first decode step."""
    cfg = TINY_FAMILIES[family]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    req = _req(cfg, plen=13)

    p1, d_mono = _pair(cfg, params, vd)
    pipe1 = DisaggPipeline(TransferEngine(), wire)
    pipe1.handoff(req, p1, d_mono)

    p2, d_stream = _pair(cfg, params, vd)
    pipe2 = DisaggPipeline(TransferEngine(), wire)
    # chunk 5 is coprime with both P(8) and D(4) block sizes → RMW path
    meta = pipe2.handoff_streamed(req, p2, d_stream, chunk_tokens=5,
                                  chunked_compute=False)
    assert meta["chunks"] == 3                      # ceil(13 / 5)
    assert pipe2.transfer.stats.chunks == 3
    assert meta["first_token"] == int(d_mono.last_token[0])

    for a, b in zip(jax.tree.leaves(d_mono.caches),
                    jax.tree.leaves(d_stream.caches)):
        assert a.dtype == b.dtype
        assert bool(jax.numpy.array_equal(a, b)), family
    np.testing.assert_array_equal(d_mono.block_tables, d_stream.block_tables)
    np.testing.assert_array_equal(d_mono.seq_lens, d_stream.seq_lens)

    tok_mono = d_mono.decode_step()[0][2]
    tok_stream = d_stream.decode_step()[0][2]
    assert tok_mono == tok_stream


def test_streamed_total_bytes_match_monolithic():
    """Chunk splitting must not change what crosses the wire: per-token
    encodings mean the summed chunk bytes equal the monolithic payload."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    req = _req(cfg, plen=13)

    p1, d1 = _pair(cfg, params, vd)
    pipe1 = DisaggPipeline(TransferEngine(), WireFormat("int8"))
    meta1 = pipe1.handoff(req, p1, d1)

    p2, d2 = _pair(cfg, params, vd)
    pipe2 = DisaggPipeline(TransferEngine(), WireFormat("int8"),
                           codec="pickle")      # legacy byte-identical wire
    meta2 = pipe2.handoff_streamed(req, p2, d2, chunk_tokens=5,
                                   chunked_compute=False)
    assert meta2["bytes"] == meta1["bytes"]
    # monolithic compute: chunks ship after all P compute, so none of the
    # wire time is hidden — no overlap credit
    st = pipe2.transfer.stats
    assert st.chunks == 3
    assert st.overlap_modeled_seconds == 0
    assert st.exposed_modeled_seconds == st.modeled_seconds

    # fixed codec: the same KV crosses the wire plus only the fixed
    # per-chunk header and 64-byte slab alignment — nothing that scales
    # with tokens
    p3, d3 = _pair(cfg, params, vd)
    pipe3 = DisaggPipeline(TransferEngine(), WireFormat("int8"))
    meta3 = pipe3.handoff_streamed(req, p3, d3, chunk_tokens=5,
                                   chunked_compute=False)
    st3 = pipe3.transfer.stats
    assert st3.chunks == 3
    overhead = meta3["bytes"] - meta1["bytes"]
    assert 0 < overhead <= st3.chunks * 1024


def test_no_empty_chunks_for_ring_or_states_families():
    """Decode only attends the last `window` tokens of a sliding prompt —
    the stream computes the whole prompt but ships nothing below the
    window floor; states-only (SSM) families ship one chunk total."""
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")

    cfg = TINY_FAMILIES["sliding"]            # window 8
    params = M.init_params(jax.random.key(1), cfg)
    p, d = _pair(cfg, params, vd)
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    meta = pipe.handoff_streamed(_req(cfg, plen=21), p, d, chunk_tokens=4)
    # wire floor 13: chunks [13,16) [16,20) [20,21), zero empty ones
    assert meta["chunks"] == 3
    assert p.stats.prefill_chunks == 6        # but every chunk computed

    cfg = TINY_FAMILIES["ssm"]
    params = M.init_params(jax.random.key(1), cfg)
    p, d = _pair(cfg, params, vd)
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    meta = pipe.handoff_streamed(_req(cfg, plen=21), p, d, chunk_tokens=4)
    assert meta["chunks"] == 1                # no KV to stream chunk-wise
    assert p.stats.prefill_chunks == 6        # state carried across chunks


def test_unsupported_prefill_mode_fails_fast():
    """Capability mismatches must raise the typed PrefillModeError, not
    silently degrade: INCREMENTAL without a chunk size, and resume on a
    family that cannot carry state."""
    from repro.serving.engine import PrefillMode, PrefillModeError
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")

    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    p, _ = _pair(cfg, params, vd)
    with pytest.raises(PrefillModeError, match="chunk_tokens"):
        p.prefill_stream(_req(cfg, plen=21), mode=PrefillMode.INCREMENTAL)
    with pytest.raises(PrefillModeError, match="mode"):
        p.prefill_stream(_req(cfg, plen=21), chunk_tokens=4,
                         mode="incremental")
    # dense is not resumable (no state, no window): a snapshot is refused
    with pytest.raises(PrefillModeError, match="resume"):
        p.prefill_stream(_req(cfg, plen=21), chunk_tokens=4,
                         resume={"seq_len": 21, "next_start": 8,
                                 "row_start": 8, "states": [], "kv": []})
    assert p.stats.resume_unsupported == 1
    # PrefillModeError is a ValueError — legacy callers still catch it
    assert issubclass(PrefillModeError, ValueError)


def test_flight_aborts_on_pinned_pool_exhaustion():
    """A pinned pool too small for one chunk must abort the flight (slot
    and blocks released), not leak the reservation out of step()."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    p, d = _pair(cfg, params, vd)
    pipe = DisaggPipeline(TransferEngine(buffer_capacity_bytes=64),
                          WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=4)
    sched.add_instance(p)
    sched.add_instance(d)
    sched.submit(_req(cfg, plen=16, rid="big", max_new=2))
    for _ in range(3):
        sched.step()                   # dispatch + failed chunk → requeue
    assert sched.stats.requeues >= 1
    assert not sched.inflight
    assert all(r is None for r in d.slot_req)      # reservation released
    assert d.allocator.free_blocks == d.allocator.num_blocks - 1


def test_permanent_failure_marks_request_failed():
    """A payload that can never fit the pinned pool must not spin the
    dispatch loop forever — after max_retries it is marked FAILED."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    p, d = _pair(cfg, params, vd)
    pipe = DisaggPipeline(TransferEngine(buffer_capacity_bytes=64),
                          WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=4, max_retries=3)
    sched.add_instance(p)
    sched.add_instance(d)
    req = _req(cfg, plen=16, rid="big", max_new=2)
    sched.submit(req)
    for _ in range(10):
        sched.step()
    assert req.state == State.FAILED
    assert sched.stats.failed == 1
    assert req.retries == 3
    assert not sched.pending and not sched.inflight
    assert all(r is None for r in d.slot_req)


def test_prefill_capabilities_matrix():
    """The capability descriptor is shared by the engine, scheduler and
    planner — pin down each family's (incremental, resumable,
    prefix_cache, encoder_preamble, kv_on_wire)."""
    expect = {
        "dense":            (True, False, True,  False, True),
        "dense-bias-qknorm": (True, False, True,  False, True),
        "moe":              (True, False, True,  False, True),
        "mla":              (True, False, True,  False, True),
        "sliding":          (True, True,  False, False, True),
        "hybrid":           (True, True,  False, False, True),
        "ssm":              (True, True,  False, False, False),
        "encdec":           (True, False, False, True,  True),
        "vlm":              (True, False, False, True,  True),
    }
    for fam, want in expect.items():
        caps = TINY_FAMILIES[fam].prefill_capabilities()
        got = (caps.incremental, caps.resumable, caps.prefix_cache,
               caps.encoder_preamble, caps.kv_on_wire)
        assert got == want, (fam, got)
        # every family now computes incrementally
        assert TINY_FAMILIES[fam].supports_chunked_prefill, fam


def test_zero_chunk_tokens_means_monolithic():
    """chunk_tokens=0 must not livelock: it degrades to the monolithic
    single-chunk stream, and a scheduler with prefill_chunk=0 takes the
    legacy single-tick path."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    req = _req(cfg, plen=13)
    p, d = _pair(cfg, params, vd)
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    meta = pipe.handoff_streamed(req, p, d, chunk_tokens=0)
    assert meta["chunks"] == 1
    assert GlobalScheduler(pipe, prefill_chunk=0).prefill_chunk is None


# --------------------------------------------------------------------- #
# 2. compute: incremental chunked prefill is token-exact end to end
# --------------------------------------------------------------------- #
def _serve_tokens(cfg, params, vd, prefill_chunk, mem_len=0, n=3,
                  plens=(21, 9, 14)):
    p, d = _pair(cfg, params, vd, mem_len=mem_len)
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=prefill_chunk)
    sched.add_instance(p)
    sched.add_instance(d)
    reqs = [_req(cfg, plen=plens[i], rid=f"q{i}", seed=i) for i in range(n)]
    done = sched.run(reqs, max_ticks=500)
    assert len(done) == n
    return {r.req_id: list(r.output_tokens) for r in reqs}, sched, p


@pytest.mark.parametrize("family", ["dense", "mla", "moe", "sliding",
                                    "hybrid"])
def test_chunked_streaming_token_exact_vs_monolithic(family):
    """Full serving stack with prefill_chunk=4 (incremental compute where
    the family supports it, chunked wire everywhere) must emit exactly the
    tokens of the monolithic scheduler."""
    cfg = TINY_FAMILIES[family]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    out_mono, _, _ = _serve_tokens(cfg, params, vd, prefill_chunk=None)
    out_chunk, sched, p = _serve_tokens(cfg, params, vd, prefill_chunk=4)
    assert out_chunk == out_mono
    assert sched.stats.chunks_streamed >= 3          # actually streamed
    if p.supports_chunked_prefill:
        assert p.stats.prefill_chunks > 3            # incremental compute
        # wire time of non-final chunks hid under the next chunk's compute
        st = sched.pipeline.transfer.stats
        assert 0 < st.overlap_modeled_seconds < st.modeled_seconds


# --------------------------------------------------------------------- #
# 3. scheduling: decode proceeds while a long prefill is in flight
# --------------------------------------------------------------------- #
def test_decode_tokens_emitted_during_long_prefill():
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    vp = VendorProfile("B", block_size=8, layout="nhbd",
                       kv_dtype="float32", tp=2)
    p0 = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
                max_seq_len=64, role="prefill")
    p1 = Engine("P1", cfg, params, vp, num_blocks=64, max_batch=4,
                max_seq_len=64, role="prefill")
    d = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
               max_seq_len=64, role="decode")
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=4, chunk_budget=1)
    for e in (p0, p1, d):
        sched.add_instance(e)

    long_req = _req(cfg, plen=40, rid="long", max_new=4, seed=11)
    short_req = _req(cfg, plen=8, rid="short", max_new=8, seed=12)
    sched.submit(long_req)
    sched.submit(short_req)

    short_while_long_prefilling = 0
    long_first_tick = None
    for tick in range(1, 60):
        emitted = sched.step()
        for r, _tok in emitted:
            if r is short_req and long_req.state == State.PREFILLING:
                short_while_long_prefilling += 1
            if r is long_req and long_first_tick is None:
                long_first_tick = tick
        if sched.stats.finished == 2:
            break

    # the long prompt needed ceil(40/4) = 10 single-chunk ticks
    assert long_first_tick is not None and long_first_tick >= 10
    assert long_req.chunks_streamed == 10
    # decode made real progress during that window — no P/D interference
    assert short_while_long_prefilling >= 4
    assert len(long_req.output_tokens) == 4
    assert len(short_req.output_tokens) == 8
    # each flight occupied its own P instance across ticks
    assert sched.stats.p_dispatches["P0"] + sched.stats.p_dispatches["P1"] == 2


def test_flight_aborts_and_requeues_on_p_failure():
    """Kill the P instance mid-stream: the D reservation must be released
    and the request re-dispatched to a healthy P, still finishing exactly."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    vp = VendorProfile("B", block_size=8, layout="nhbd",
                       kv_dtype="float32", tp=2)
    p0 = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
                max_seq_len=64, role="prefill")
    p1 = Engine("P1", cfg, params, vp, num_blocks=64, max_batch=4,
                max_seq_len=64, role="prefill")
    d = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
               max_seq_len=64, role="decode")
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=4, chunk_budget=1)
    for e in (p0, p1, d):
        sched.add_instance(e)

    req = _req(cfg, plen=32, rid="rq", max_new=4, seed=5)
    sched.submit(req)
    sched.step()
    sched.step()                       # a couple of chunks in flight on P0/P1
    victim = sched.inflight[0].p
    victim.fail()
    for _ in range(80):
        if sched.stats.finished == 1:
            break
        sched.step()
    assert sched.stats.finished == 1
    assert sched.stats.requeues >= 1
    assert len(req.output_tokens) == 4
    # reservation was not leaked: every D slot is free again
    assert all(r is None for r in d.slot_req)
    assert d.allocator.free_blocks == d.allocator.num_blocks - 1  # scratch


def test_flight_requeues_once_on_d_failure():
    """Kill the D instance mid-stream: the request must be requeued exactly
    once (a stale slot entry must not resurrect it a second time) and
    finish with exactly max_new_tokens."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    vp = VendorProfile("B", block_size=8, layout="nhbd",
                       kv_dtype="float32", tp=2)
    p = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
               max_seq_len=64, role="prefill")
    d0 = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
                max_seq_len=64, role="decode")
    d1 = Engine("D1", cfg, params, vd, num_blocks=64, max_batch=4,
                max_seq_len=64, role="decode")
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=4, chunk_budget=1)
    for e in (p, d0, d1):
        sched.add_instance(e)

    req = _req(cfg, plen=24, rid="rq", max_new=4, seed=9)
    sched.submit(req)
    sched.step()
    sched.step()
    assert len(sched.inflight) == 1
    sched.inflight[0].d.fail()          # decode node dies mid-stream
    for _ in range(80):
        if sched.stats.finished >= 1:
            break
        sched.step()
    # exactly one life: one finish, exactly max_new tokens, one requeue
    assert sched.stats.finished == 1
    assert sched.stats.requeues == 1
    assert len(req.output_tokens) == 4
    assert req.state == State.FINISHED
    assert not sched.inflight and not sched.pending
