"""Smoke: train a tiny dense LM for 30 steps; loss must drop. Checkpoint
save/restore roundtrip; compression psum sanity."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.training.data import DataConfig, SyntheticTokens
from repro.training.optim import AdamWConfig
from repro.training.train_step import make_train_step, train_state_init
from repro.training.checkpoint import CheckpointManager

cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  param_dtype="float32", compute_dtype="float32")
opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=200)
state = train_state_init(jax.random.key(0), cfg)
step = jax.jit(make_train_step(cfg, opt))
data = iter(SyntheticTokens(cfg, DataConfig(batch_size=8, seq_len=32, seed=1)))

losses = []
for i in range(30):
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    state, m = step(state, batch)
    losses.append(float(m["loss"]))
print(f"loss[0]={losses[0]:.3f} loss[-1]={losses[-1]:.3f}")
assert losses[-1] < losses[0] - 0.2, "loss did not drop"

with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d, keep=2)
    mgr.save(30, state, meta={"cfg": cfg.name})
    mgr.save(31, state)
    mgr.save(32, state)
    mgr.wait()
    assert mgr.all_steps() == [31, 32], mgr.all_steps()
    restored = mgr.restore(32, like=jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("[ok] checkpoint roundtrip + gc")

# compression
from repro.training.compression import quantize_int8, dequantize_int8
x = jax.random.normal(jax.random.key(2), (128, 64))
q, s = quantize_int8(x)
err = jnp.max(jnp.abs(dequantize_int8(q, s) - x)) / jnp.max(jnp.abs(x))
assert err < 1 / 64, err
print(f"[ok] int8 compress max rel err {float(err):.4f}")
print("TRAINING OK")
