"""Scratch: how close is incremental chunked prefill (decode-path over a
dense prompt-capacity cache) to monolithic M.prefill, numerically?

Finding (drove the PR-1 design): NOT bitwise in general — fp reassociation
at ~1e-6 (one family happens to be exact), though greedy tokens agree. So
the bit-for-bit guarantee of the streamed handoff is made at the *wire*
layer (per-token encodings + RMW re-paging), while chunked *compute* is
held to token-exactness — see tests/test_chunked_handoff.py.

  PYTHONPATH=src python scratch/check_chunk_equiv.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig
from repro.models import model as M


def tiny(name, **kw) -> ModelConfig:
    base = dict(name=name, family="dense", num_layers=3, d_model=64,
                num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                vocab_size=128, param_dtype="float32",
                compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


FAMILIES = {
    "dense": tiny("dense"),
    "dense-bias-qknorm": tiny("dense-bias-qknorm", qkv_bias=True,
                              qk_norm=True, num_kv_heads=2),
    "mla": tiny("mla", attention_kind="mla",
                mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                              qk_rope_head_dim=8, v_head_dim=16)),
    "moe": tiny("moe", family="moe",
                moe=MoEConfig(num_experts=4, num_shared_experts=1, top_k=2,
                              d_ff_expert=32, first_dense_layers=1)),
}


def run(fam, plen=13, chunk=4):
    cfg = FAMILIES[fam]
    params = M.init_params(jax.random.key(1), cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, plen), jnp.int32)[None]

    # monolithic
    caches0 = M.init_caches(cfg, 1, plen, cfg.cdtype)
    last_full, caches_full = M.prefill(params, cfg, {"tokens": tokens}, caches0)

    # chunked (decode path over the growing cache)
    caches = M.init_caches(cfg, 1, plen, cfg.cdtype)
    last = None
    for c0 in range(0, plen, chunk):
        c1 = min(c0 + chunk, plen)
        pos = jnp.arange(c0, c1, dtype=jnp.int32)[None]
        last, caches = M.decode_step(params, cfg, tokens[:, c0:c1], pos, caches)
    last_chunk = last[:, -1]

    ok_logits = bool(jnp.array_equal(last_full, last_chunk))
    same_tok = int(jnp.argmax(last_full)) == int(jnp.argmax(last_chunk))
    leaves_f = jax.tree.leaves(caches_full)
    leaves_c = jax.tree.leaves(caches)
    kv_exact = all(bool(jnp.array_equal(a, b)) for a, b in zip(leaves_f, leaves_c))
    maxdiff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                  if a.dtype != jnp.int32 else 0.0
                  for a, b in zip(leaves_f, leaves_c))
    print(f"{fam:18s} logits_exact={ok_logits} tok_same={same_tok} "
          f"kv_exact={kv_exact} maxdiff={maxdiff:.3e}")


if __name__ == "__main__":
    for fam in FAMILIES:
        run(fam)
