"""Scratch: planner sanity — paper-claims directionality on Llama2-7B with
GPU A (decode-strong VRAM) + GPU B (prefill-strong compute)."""
import numpy as np

from repro.configs import get_config
from repro.core.planner.events import simulate
from repro.core.planner.hardware import GPU_A, GPU_B
from repro.core.planner.optimizer import plan_deployment
from repro.core.planner.simulator import InstanceModel, ParallelStrategy
from repro.core.planner.workload import FIG7, FIG8, FIG9, FIG10, Workload

cfg = get_config("llama2-7b")

# --- layered model sanity
for hw in (GPU_A, GPU_B):
    m = InstanceModel(cfg, hw, ParallelStrategy(tp=1))
    lp_256 = m.prefill_latency(256)
    lp_1024 = m.prefill_latency(1024)
    ld_b1 = m.decode_latency(1, 512)
    ld_b16 = m.decode_latency(16, 512)
    print(f"{hw.name}: l_p(256)={lp_256*1e3:.1f}ms l_p(1024)={lp_1024*1e3:.1f}ms "
          f"l_d(b1)={ld_b1*1e3:.2f}ms l_d(b16)={ld_b16*1e3:.2f}ms "
          f"weights={m.weight_bytes_per_gpu()/2**30:.1f}GiB")
    assert lp_1024 > lp_256 * 2.5
    assert ld_b16 < ld_b1 * 4  # memory-bound: batch is nearly free

# GPU B (more FLOPs) should prefill faster; GPU A (more HBM BW) decode faster
mA = InstanceModel(cfg, GPU_A, ParallelStrategy())
mB = InstanceModel(cfg, GPU_B, ParallelStrategy())
assert mB.prefill_latency(1024) < mA.prefill_latency(1024) * 1.2
assert mA.decode_latency(16, 1024) < mB.decode_latency(16, 1024)
print("[ok] vendor asymmetry: B prefills faster, A decodes faster")

# --- two-stage optimizer
for wl in (FIG7, FIG8):
    plan = plan_deployment(cfg, wl, p_hw=GPU_B, d_hw=GPU_A)
    print(f"{wl.label()}: P={plan.prefill.strategy.label()} x{plan.n_prefill} "
          f"(l_p={plan.prefill.latency_s*1e3:.0f}ms) "
          f"D={plan.decode.strategy.label()} x{plan.n_decode} "
          f"(l_d={plan.decode.latency_s*1e3:.1f}ms, batch={plan.decode.batch}) "
          f"cost={plan.cost_per_hour:.1f}$/h qps_cap={plan.qps_capacity:.2f}")
    assert plan.qps_capacity >= wl.qps * 0.99

# --- event sim: Fig 6 directionality (TTFT grows with input len; flat in output)
wl_a = Workload(qps=2, input_len=256, output_len=256)
wl_b = Workload(qps=2, input_len=1024, output_len=256)
wl_c = Workload(qps=2, input_len=256, output_len=1024)
mP = InstanceModel(cfg, GPU_B, ParallelStrategy())
mD = InstanceModel(cfg, GPU_A, ParallelStrategy())
r_a = simulate(cfg, wl_a, p_model=mP, d_model=mD, duration_s=60)
r_b = simulate(cfg, wl_b, p_model=mP, d_model=mD, duration_s=60)
r_c = simulate(cfg, wl_c, p_model=mP, d_model=mD, duration_s=60)
print(f"fig6: ttft(in256)={r_a.ttft_mean():.3f}s ttft(in1024)={r_b.ttft_mean():.3f}s "
      f"ttft(out1024)={r_c.ttft_mean():.3f}s tput={r_a.throughput_tok_s():.0f} "
      f"vs {r_b.throughput_tok_s():.0f} tok/s")
assert r_b.ttft_mean() > r_a.ttft_mean() * 1.5
assert abs(r_c.ttft_mean() - r_a.ttft_mean()) < 0.3 * r_a.ttft_mean()

# --- fig7/8: P:D ratio saturation
wl = FIG7
res = {}
for (np_, nd) in [(1, 1), (2, 1), (3, 1), (1, 2), (1, 3)]:
    r = simulate(cfg, wl, p_model=mP, d_model=mD, n_prefill=np_, n_decode=nd,
                 duration_s=60)
    res[(np_, nd)] = r
    print(f"{np_}P{nd}D @ {wl.label()}: ttft={r.ttft_mean():.3f} "
          f"tpot={r.tpot_mean()*1e3:.1f}ms tput={r.throughput_tok_s():.0f}")
# saturation: 2P1D ≈ 3P1D on short context (paper Fig. 7)
a, b = res[(2, 1)].throughput_tok_s(), res[(3, 1)].throughput_tok_s()
assert abs(a - b) / a < 0.05, (a, b)
print("[ok] P:D ratio saturation on short context")

# --- fig9/10: disagg vs integrated at long ctx / high qps.
# Cost-fair: same hardware both sides — disagg: P on GPU B, D on GPU A;
# integrated: the same {GPU B, GPU A} pair, each instance doing both stages.
for wl in (FIG9, FIG10):
    r_dis = simulate(cfg, wl, p_model=mP, d_model=mD, n_prefill=1, n_decode=1,
                     duration_s=120)
    r_int = simulate(cfg, wl, p_model=mP, d_model=mD, n_prefill=1, n_decode=1,
                     mode="integrated", duration_s=120)
    gain = (r_dis.throughput_tok_s() - r_int.throughput_tok_s()) / \
        r_int.throughput_tok_s()
    print(f"{wl.label()}: disagg {r_dis.throughput_tok_s():.0f} tok/s "
          f"(ttft {r_dis.ttft_mean():.2f}s, tpot {r_dis.tpot_mean()*1e3:.1f}ms) "
          f"vs integrated {r_int.throughput_tok_s():.0f} tok/s "
          f"(ttft {r_int.ttft_mean():.2f}s, tpot {r_int.tpot_mean()*1e3:.1f}ms) "
          f"gain {gain*100:.0f}%")

print("PLANNER OK")
