"""Render §Dry-run and §Roofline tables from results/dryrun.jsonl into
EXPERIMENTS.md (replacing the <!-- DRYRUN_TABLE --> / <!-- ROOFLINE_TABLE -->
markers)."""
import json
import sys

sys.path.insert(0, "benchmarks")
sys.path.insert(0, "src")
from benchmarks.roofline_table import load  # noqa: E402

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def dryrun_table(recs):
    lines = [
        "Per-cell dry-run evidence (GiB/chip = arguments + outputs + temps "
        "− aliased; both meshes compile for every non-skipped cell):",
        "",
        "| arch | shape | mode | single-pod GiB/chip | multi-pod GiB/chip "
        "| compile s (single) |",
        "|---|---|---|---|---|---|",
    ]
    by = {}
    for r in recs:
        by.setdefault((r["arch"], r["shape"]), {})[r["mesh"]] = r
    for (arch, shape) in sorted(by, key=lambda k: (k[0], ORDER.index(k[1]))):
        cell = by[(arch, shape)]
        r = cell.get("single") or cell.get("multi")
        if r.get("skip"):
            lines.append(f"| {arch} | {shape} | — | skip | skip | — "
                         f"({r['skip'].split(':')[0]}) |")
            continue
        s = cell.get("single", {})
        m = cell.get("multi", {})
        gs = s.get("memory_analysis", {}).get("total_minus_aliased")
        gm = m.get("memory_analysis", {}).get("total_minus_aliased")
        cs = s.get("seconds", {}).get("compile", "—")
        lines.append(
            f"| {arch} | {shape} | {r['mode']} "
            f"| {gs/2**30:.1f} | {gm/2**30 if gm else float('nan'):.1f} "
            f"| {cs} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | mode | compute s | memory s (corr / raw) "
        "| collective s | bound | useful | top collective |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    census = {}
    for r in sorted(recs, key=lambda r: (r["arch"], ORDER.index(r["shape"]))):
        if r["mesh"] != "single":
            continue
        if r.get("skip"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — "
                         f"| skip | — | — |")
            continue
        rl = r.get("roofline") or {}
        if "seconds" not in rl:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mode']} | — "
                         f"| — | — | (no probe) | — | — |")
            continue
        s = rl["seconds"]
        top = max(rl.get("by_kind", {"—": 0}).items(),
                  key=lambda kv: kv[1])[0]
        census[rl["dominant"]] = census.get(rl["dominant"], 0) + 1
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mode']} "
            f"| {s['compute']:.3f} | {s['memory']:.3f} / "
            f"{s.get('memory_raw', s['memory']):.3f} "
            f"| {s['collective']:.3f} | {rl['dominant']} "
            f"| {rl['useful_ratio']:.2f} | {top} |")
    lines.append("")
    lines.append(f"Bottleneck census: {census}.")
    return "\n".join(lines)


def main():
    recs = load("results/dryrun.jsonl")
    text = open("EXPERIMENTS.md").read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_table(recs))
    text = text.replace("<!-- ROOFLINE_TABLE -->",
                        roofline_table([r for r in recs
                                        if r["mesh"] == "single"]))
    open("EXPERIMENTS.md", "w").write(text)
    print("tables rendered into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
