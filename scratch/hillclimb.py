"""§Perf hillclimb driver — three selected cells, hypothesis-driven
variants, before/after roofline terms. Appends records to
results/hillclimb.jsonl.

  PYTHONPATH=src python scratch/hillclimb.py [cellA cellB ...]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
                           "while-loop-expensive-invariant-code-motion")
import dataclasses
import json
import sys
import time

from repro.launch.cells import get_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_artifacts
from repro.roofline import analysis as RA

MESH = make_production_mesh()
OUT = "results/hillclimb.jsonl"


def measure(cell, label, **kw):
    """Probe-extrapolated roofline terms + full-artifact memory for a
    variant of a cell."""
    t0 = time.time()
    plan = RA.probe_plan(cell.arch)
    acc = []
    for override, coeff in plan:
        art = make_artifacts(cell, MESH, unroll=True,
                             layer_override=override, **kw)
        compiled = art.lower().compile()
        acc.append((RA.analyze_compiled(compiled, 16), coeff))
    terms = RA.roofline_for_cell(acc)
    # full artifact: memory proof
    art = make_artifacts(cell, MESH, **kw)
    ma = art.lower().compile().memory_analysis()
    tot = (ma.argument_size_in_bytes + ma.output_size_in_bytes
           + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    s = terms.seconds()
    rec = {
        "cell": f"{cell.arch}@{cell.shape}", "variant": label,
        "kw": {k: str(v) for k, v in kw.items()},
        "n_micro": cell.n_micro,
        "compute_s": s["compute"], "memory_s": s["memory"],
        "memory_raw_s": s["memory_raw"], "collective_s": s["collective"],
        "dominant": terms.dominant(), "step_time_s": terms.step_time(),
        "mem_gib": tot / 2**30,
        "by_kind_mib": {k: round(v / 2**20, 1)
                        for k, v in terms.by_kind.items()},
        "wall_s": round(time.time() - t0, 1),
    }
    print(f"[{rec['cell']} :: {label}] compute {s['compute']:.3f}s "
          f"memory {s['memory']:.3f}s (raw {s['memory_raw']:.3f}) "
          f"collective {s['collective']:.3f}s → {rec['dominant']}, "
          f"step {rec['step_time_s']:.3f}s, fits {rec['mem_gib']:.1f} GiB "
          f"({rec['wall_s']:.0f}s)", flush=True)
    with open(OUT, "a") as fh:
        fh.write(json.dumps(rec) + "\n")
    return rec


def cell_A_phi3_prefill():
    """Worst roofline fraction: phi3 prefill_32k (memory 55.8s vs compute
    3.3s at baseline). Dominant-term attack: chunked-attention accumulator
    RMW traffic scales with chunk COUNT — quadruple the chunk."""
    cell = get_cell("phi3-medium-14b", "prefill_32k")
    base = measure(cell, "baseline(chunk=1024)")
    # iteration 1: fewer chunks → fewer acc read-modify-writes
    it1 = measure(cell, "chunk=4096", chunk_size=4096)
    # iteration 2: push further — 8192 (score buffer grows 8×; check fit)
    it2 = measure(cell, "chunk=8192", chunk_size=8192)
    return [base, it1, it2]


def cell_B_mixtral_train():
    """Most collective-bound: mixtral train_4k (collective 12.3s).
    ZeRO-3 weight all-gathers repeat PER MICROBATCH — fewer micros move
    fewer weight bytes; sequence-parallel residuals pay the freed
    activation memory back."""
    cell = get_cell("mixtral-8x7b", "train_4k")
    base = measure(cell, "baseline(n_micro=16)")
    it1 = measure(dataclasses.replace(cell, n_micro=8), "n_micro=8")
    it2 = measure(dataclasses.replace(cell, n_micro=8),
                  "n_micro=8+act_seq", act_seq=True)
    it3 = measure(dataclasses.replace(cell, n_micro=4),
                  "n_micro=4+act_seq", act_seq=True)
    return [base, it1, it2, it3]


def cell_C_qwen15_decode():
    """Most paper-representative: qwen1.5-32b decode_32k — one token vs a
    32k fp8 KV cache (precision-alignment lever). Baseline memory is
    dominated by fp8→f32 emulation converts (subtracted) and the ideal
    floor is cache+weights ≈ 14.9 GiB → 18 ms."""
    cell = get_cell("qwen1.5-32b", "decode_32k")
    base = measure(cell, "baseline(fp8-kv)")
    # iteration 1: bf16 cache (paper-faithful precision) for comparison —
    # memory_analysis will show the capacity blowout that motivated fp8
    it1 = measure(dataclasses.replace(cell, cache_dtype="bfloat16"),
                  "bf16-kv(paper-faithful)")
    return [base, it1]


ALL = {"A": cell_A_phi3_prefill, "B": cell_B_mixtral_train,
       "C": cell_C_qwen15_decode}

if __name__ == "__main__":
    which = sys.argv[1:] or ["A", "B", "C"]
    for w in which:
        ALL[w]()
