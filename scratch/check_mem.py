import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
import numpy as np

from repro.launch.cells import get_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_artifacts

cell = get_cell("qwen3-4b", "decode_32k")
mesh = make_production_mesh()
art = make_artifacts(cell, mesh)

# per-leaf bytes per chip, by argument group
def tree_bytes_per_chip(abs_tree, sh_tree, label):
    tot = 0
    items = []
    for (kp, leaf), sh in zip(
            jax.tree_util.tree_flatten_with_path(abs_tree)[0],
            jax.tree.leaves(sh_tree, is_leaf=lambda x: hasattr(x, "spec"))):
        n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        # shard fraction
        frac = 1.0
        spec = sh.spec
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            frac /= size
        b = n * frac
        tot += b
        items.append((b, jax.tree_util.keystr(kp), spec))
    items.sort(reverse=True)
    print(f"== {label}: {tot/2**30:.2f} GiB/chip")
    for b, k, spec in items[:6]:
        print(f"   {b/2**20:9.1f} MiB  {k}  {spec}")
    return tot

p = tree_bytes_per_chip(art.abstract_args[0], art.in_shardings[0], "params")
c = tree_bytes_per_chip(art.abstract_args[1], art.in_shardings[1], "caches")

lowered = art.lower()
compiled = lowered.compile()
ma = compiled.memory_analysis()
for f in ("argument_size_in_bytes", "output_size_in_bytes",
          "temp_size_in_bytes", "alias_size_in_bytes"):
    print(f, getattr(ma, f, None) / 2**30, "GiB")
