import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re
import jax

from repro.launch.cells import get_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_artifacts

cell = get_cell("deepseek-v2-lite-16b", "prefill_32k")
mesh = make_production_mesh()
art = make_artifacts(cell, mesh, layer_override={"num_layers": 2})
compiled = art.lower().compile()
ma = compiled.memory_analysis()
print("temp GiB (2 layers):", ma.temp_size_in_bytes / 2**30)

txt = compiled.as_text()
BYTES = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "pred": 1, "f8e4m3fn": 1}
sizes = {}
for m in re.finditer(r"(\w+)\[([\d,]+)\]", txt):
    dt, dims = m.group(1), m.group(2)
    if dt not in BYTES:
        continue
    n = 1
    for d in dims.split(","):
        n *= int(d)
    b = n * BYTES[dt]
    key = f"{dt}[{dims}]"
    if b > 100e6:
        sizes[key] = max(sizes.get(key, 0), b)
for k, v in sorted(sizes.items(), key=lambda kv: -kv[1])[:15]:
    print(f"{v/2**30:8.2f} GiB  {k}")
