"""Scratch: end-to-end disaggregated serving == integrated serving, across
heterogeneous vendor profiles (block size / layout / dtype / TP mismatch),
for every cache family (dense GQA, SWA, MLA, hybrid, SSM, enc-dec, VLM)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, RECURRENT, ModelConfig, MoEConfig,
                                MLAConfig, SSMConfig, RecurrentConfig,
                                FrontendConfig)
from repro.core.disagg import DisaggPipeline
from repro.core.kv_transfer import TransferEngine
from repro.core.compat.precision import WireFormat
from repro.models import model as M
from repro.serving.engine import Engine, VendorProfile
from repro.serving.request import Request
from repro.serving.scheduler import GlobalScheduler
from repro.serving.server import Server


def tiny(name, **kw):
    base = dict(name=name, family="dense", num_layers=3, d_model=64,
                num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                vocab_size=128, param_dtype="float32", compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


CASES = [
    ("dense", tiny("dense"),
     VendorProfile("vendorB", block_size=8, layout="nhbd", kv_dtype="float32", tp=2),
     VendorProfile("vendorA", block_size=4, layout="nbhd", kv_dtype="float32", tp=1)),
    ("swa", tiny("swa", attention_kind="sliding", sliding_window=8),
     VendorProfile("vendorB", block_size=4, layout="nhdb", kv_dtype="float32", tp=4),
     VendorProfile("vendorA", block_size=8, layout="nbhd", kv_dtype="float32", tp=2)),
    ("mla", tiny("mla", attention_kind="mla",
                 mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                               qk_rope_head_dim=8, v_head_dim=16)),
     VendorProfile("vendorB", block_size=8, layout="nhbd", kv_dtype="float32", tp=2),
     VendorProfile("vendorA", block_size=4, layout="nbhd", kv_dtype="float32", tp=1)),
    ("hybrid", tiny("hybrid", family="hybrid", attention_kind="sliding",
                    sliding_window=8, num_layers=5,
                    recurrent=RecurrentConfig(lru_width=64, d_conv=4,
                                              block_pattern=(RECURRENT, RECURRENT, ATTN))),
     VendorProfile("vendorB", block_size=8, layout="nbhd", kv_dtype="float32", tp=1),
     VendorProfile("vendorA", block_size=4, layout="nhbd", kv_dtype="float32", tp=1)),
    ("ssm", tiny("ssm", family="ssm", attention_kind="none", num_kv_heads=0,
                 d_ff=0, num_heads=8,
                 ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4,
                               chunk_size=4)),
     VendorProfile("vendorB", block_size=8, layout="nbhd", kv_dtype="float32", tp=1),
     VendorProfile("vendorA", block_size=8, layout="nbhd", kv_dtype="float32", tp=1)),
    ("encdec", tiny("encdec", family="audio", encoder_layers=2,
                    frontend=FrontendConfig(kind="audio")),
     VendorProfile("vendorB", block_size=8, layout="nhbd", kv_dtype="float32", tp=2),
     VendorProfile("vendorA", block_size=4, layout="nbhd", kv_dtype="float32", tp=1)),
    ("vlm", tiny("vlm", family="vlm", num_kv_heads=2,
                 frontend=FrontendConfig(kind="vision", num_patches=4)),
     VendorProfile("vendorB", block_size=8, layout="nbhd", kv_dtype="float32", tp=2),
     VendorProfile("vendorA", block_size=4, layout="nhdb", kv_dtype="float32", tp=1)),
]

rng = np.random.default_rng(7)

for name, cfg, vp, vd in CASES:
    params = M.init_params(jax.random.key(1), cfg)
    mem_len = 10 if cfg.is_enc_dec else 0

    def mk_reqs(n=3):
        rng = np.random.default_rng(7)   # identical requests for both systems
        reqs = []
        for i in range(n):
            plen = int(rng.integers(5, 12))
            r = Request(req_id=f"{name}-{i}",
                        prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                        max_new_tokens=6)
            if cfg.is_enc_dec:
                r.frames = rng.normal(size=(mem_len, cfg.d_model)).astype(np.float32)
            if cfg.frontend.kind == "vision":
                r.patches = rng.normal(size=(cfg.frontend.num_patches,
                                             cfg.d_model)).astype(np.float32)
            reqs.append(r)
        return reqs

    # --- disaggregated: heterogeneous P and D instances
    p_eng = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
                   max_seq_len=64, mem_len=mem_len, role="prefill")
    d_eng = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
                   max_seq_len=64, mem_len=mem_len, role="decode")
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe)
    sched.add_instance(p_eng)
    sched.add_instance(d_eng)
    reqs_a = mk_reqs()
    Server(sched).serve(reqs_a, max_ticks=200)
    out_disagg = {r.req_id: list(r.output_tokens) for r in reqs_a}

    # --- integrated baseline: one instance does both (same vendor, no wire)
    both = Engine("I0", cfg, params,
                  VendorProfile("vendorA", block_size=8, layout="nbhd",
                                kv_dtype="float32", tp=1),
                  num_blocks=64, max_batch=4, max_seq_len=64,
                  mem_len=mem_len, role="both")
    pipe2 = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    sched2 = GlobalScheduler(pipe2)
    sched2.add_instance(both)
    reqs_b = mk_reqs()
    Server(sched2).serve(reqs_b, max_ticks=200)
    out_integrated = {r.req_id: list(r.output_tokens) for r in reqs_b}

    for rid in out_disagg:
        assert out_disagg[rid] == out_integrated[rid], \
            (name, rid, out_disagg[rid], out_integrated[rid])
    print(f"[ok] {name}: disaggregated tokens == integrated tokens "
          f"({sum(len(v) for v in out_disagg.values())} tokens, "
          f"{pipe.transfer.stats.bytes_moved} wire bytes)")

print("DISAGG == INTEGRATED FOR ALL FAMILIES")
