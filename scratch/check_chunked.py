"""chunked_sdpa == sdpa+mask; chunked MLA == naive; moe shard_map == local."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import dist
from repro.configs.base import ModelConfig, MoEConfig

rng = jax.random.PRNGKey(0)

# --- chunked GQA attention (causal, window, lengths) ----------------------
b, s, h, kv, hd = 2, 37, 8, 4, 16
ks = jax.random.split(rng, 4)
q = jax.random.normal(ks[0], (b, s, h, hd))
k = jax.random.normal(ks[1], (b, s, kv, hd))
v = jax.random.normal(ks[2], (b, s, kv, hd))
lengths = jnp.asarray([37, 21])
positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
positions = jnp.where(positions < lengths[:, None], positions, -1)

for window in (0, 9):
    mask = L.causal_mask(s, s, 0, window) + L.length_mask(lengths, s)
    ref = L.sdpa(q, k, v, mask)
    out = L.chunked_sdpa(q, k, v, positions, positions, causal=True,
                         window=window, chunk=8)
    # rows beyond length are garbage in both; compare valid rows
    for i in range(b):
        nv = int(lengths[i])
        np.testing.assert_allclose(out[i, :nv], ref[i, :nv], atol=2e-5)
print("[ok] chunked_sdpa == sdpa (causal, window, ragged lengths)")

# --- chunked MLA ----------------------------------------------------------
cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=64,
                  attention_kind="mla", param_dtype="float32",
                  compute_dtype="float32")
from repro.configs.base import MLAConfig
cfg = cfg.with_(mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                              qk_rope_head_dim=8, v_head_dim=16))
p = L.init_mla(ks[3], cfg)
x = jax.random.normal(rng, (b, s, 64))
ref_out, _ = L.mla_block(p, cfg, x, positions, lengths)
with dist.use(dist.DistContext(chunk_kv=8, chunk_size=8)):
    chk_out, _ = L.mla_block(p, cfg, x, positions, lengths)
for i in range(b):
    nv = int(lengths[i])
    np.testing.assert_allclose(chk_out[i, :nv], ref_out[i, :nv], atol=2e-5)
print("[ok] chunked MLA == naive MLA")

# --- moe shard_map == local (on a small local mesh) ------------------------
mcfg = ModelConfig(name="m", family="moe", num_layers=2, d_model=32,
                   num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
                   moe=MoEConfig(num_experts=4, num_shared_experts=1,
                                 top_k=2, d_ff_expert=16),
                   param_dtype="float32", compute_dtype="float32")
mp = L.init_moe(jax.random.PRNGKey(7), mcfg)
xm = jax.random.normal(jax.random.PRNGKey(8), (2, 6, 32))
ref_y = L.moe_mlp(mp, mcfg, xm)
mesh = jax.make_mesh((1, 1), ("data", "model"))
with dist.use(dist.DistContext(mesh=mesh, dp_axes=("data",),
                               model_axis="model", moe_shard_map=True)):
    dist_y = jax.jit(lambda p_, x_: L.moe_mlp(p_, mcfg, x_))(mp, xm)
np.testing.assert_allclose(np.asarray(dist_y), np.asarray(ref_y), atol=1e-5)
print("[ok] moe shard_map == local")
print("CHUNKED/DIST LAYERS OK")
