import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import numpy as np

print("devices:", len(jax.devices()))

# --- 1. cost_analysis vs scan trip count -------------------------------
def body(x, w):
    return x @ w, None

def scanned(x, ws):
    y, _ = jax.lax.scan(body, x, ws)
    return y

def unrolled(x, ws):
    for i in range(ws.shape[0]):
        x = x @ ws[i]
    return x

x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
cs = jax.jit(scanned).lower(x, ws).compile().cost_analysis()
cu = jax.jit(unrolled).lower(x, ws).compile().cost_analysis()
print("scan flops:", cs.get("flops"), " unrolled flops:", cu.get("flops"),
      " expected:", 8 * 2 * 128 * 256 * 256)

# --- 2. mesh 512 + uneven sharding (8 over 16) --------------------------
mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
print("mesh ok:", mesh.shape)

w = jax.ShapeDtypeStruct((1024, 8, 128), jnp.bfloat16)   # kv=8 over model=16
xin = jax.ShapeDtypeStruct((32, 64, 1024), jnp.bfloat16)

def f(x, w):
    return jnp.einsum("bsd,dhk->bshk", x, w)

shw = NamedSharding(mesh, P(None, "model", None))
shx = NamedSharding(mesh, P(("pod", "data"), None, None))
try:
    lowered = jax.jit(f, in_shardings=(shx, shw)).lower(xin, w)
    comp = lowered.compile()
    print("uneven shard ok; per-dev flops:", comp.cost_analysis().get("flops"))
except Exception as e:
    print("uneven shard FAILED:", type(e).__name__, str(e)[:200])

# --- 3. fp8 on cpu ------------------------------------------------------
try:
    def g(k):
        return k.astype(jnp.float32).sum()
    kk = jax.ShapeDtypeStruct((64, 64), jnp.float8_e4m3fn)
    jax.jit(g).lower(kk).compile()
    print("fp8 compile ok")
except Exception as e:
    print("fp8 FAILED:", type(e).__name__, str(e)[:200])

# --- 4. memory_analysis fields ------------------------------------------
ma = comp.memory_analysis()
print("memory_analysis:", ma)

# --- 5. collective ops in HLO text ---------------------------------------
def h(x, w):
    y = jnp.einsum("bsd,dhk->bshk", x, w)
    return y.sum(axis=(1, 2, 3))

lw = jax.jit(h, in_shardings=(shx, shw), out_shardings=NamedSharding(mesh, P(("pod","data")))).lower(xin, w)
txt = lw.compile().as_text()
import re
colls = re.findall(r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)[^\n]*", txt)
print("collectives found:", len(colls))
for c in colls[:5]:
    print("  ", c[:160])
