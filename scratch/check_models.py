"""Scratch: validate every arch family — forward shapes, prefill/decode parity."""
import sys
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, RECURRENT, ModelConfig, MoEConfig,
                                MLAConfig, SSMConfig, RecurrentConfig,
                                FrontendConfig)
from repro.models import model as M

jax.config.update("jax_platform_name", "cpu")


def tiny(name, **kw):
    base = dict(name=name, family="dense", num_layers=4, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=256, param_dtype="float32", compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


CFGS = [
    tiny("dense"),
    tiny("dense-bias-qknorm", qkv_bias=True, qk_norm=True),
    tiny("sliding", attention_kind="sliding", sliding_window=8),
    tiny("mla", attention_kind="mla", num_kv_heads=4,
         mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                       qk_rope_head_dim=8, v_head_dim=16)),
    tiny("moe", family="moe",
         moe=MoEConfig(num_experts=4, num_shared_experts=1, top_k=2,
                       d_ff_expert=32, first_dense_layers=1)),
    tiny("hybrid", family="hybrid", attention_kind="sliding", sliding_window=8,
         num_layers=5,
         recurrent=RecurrentConfig(lru_width=64, d_conv=4,
                                   block_pattern=(RECURRENT, RECURRENT, ATTN),
                                   local_window=8)),
    tiny("ssm", family="ssm", attention_kind="none", num_kv_heads=0, d_ff=0,
         num_heads=8,
         ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4,
                       chunk_size=4, n_groups=1)),
    tiny("encdec", family="audio", encoder_layers=3,
         frontend=FrontendConfig(kind="audio", downsample=2)),
    tiny("vlm", family="vlm",
         frontend=FrontendConfig(kind="vision", num_patches=4)),
]

B, S = 2, 12
GEN = 5
rng = np.random.default_rng(0)

for cfg in CFGS:
    key = jax.random.key(0)
    params = M.init_params(key, cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + GEN)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_enc_dec:
        batch["frames"] = jnp.asarray(rng.normal(size=(B, 10, cfg.d_model)), jnp.float32)
    if cfg.frontend.kind == "vision":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend.num_patches, cfg.d_model)), jnp.float32)

    # train forward
    logits = M.train_forward(params, cfg, batch, remat=True)
    exp_s = S + GEN + (cfg.frontend.num_patches if cfg.frontend.kind == "vision" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab_size), (cfg.name, logits.shape)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), cfg.name
    loss = M.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss)), cfg.name

    # prefill/decode parity vs full forward
    cap = S + GEN + (cfg.frontend.num_patches if cfg.frontend.kind == "vision" else 0)
    caches = M.init_caches(cfg, B, cap, jnp.float32, mem_len=10)
    inputs = {k: v for k, v in batch.items() if k != "labels"}
    inputs["tokens"] = tokens[:, :S]
    last, caches = M.prefill(params, cfg, inputs, caches)
    off = cfg.frontend.num_patches if cfg.frontend.kind == "vision" else 0
    full = M.train_forward(params, cfg, batch, remat=False)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, off + S - 1]),
                               rtol=2e-4, atol=2e-4, err_msg=f"{cfg.name} prefill")
    for t in range(GEN):
        pos = jnp.full((B, 1), off + S + t, jnp.int32)
        step_logits, caches = M.decode_step(params, cfg, tokens[:, S + t:S + t + 1],
                                            pos, caches)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]), np.asarray(full[:, off + S + t]),
            rtol=2e-4, atol=2e-4, err_msg=f"{cfg.name} decode step {t}")
    print(f"[ok] {cfg.name}: train {logits.shape}, loss {float(loss):.3f}, "
          f"prefill+{GEN} decode steps match full forward")

print("ALL MODEL FAMILIES OK")
